"""Pallas TPU flash attention (prefill/training forward).

Grid: (batch*heads, q_blocks, kv_blocks) with the KV dimension innermost and
sequential; online-softmax statistics (m, l) and the output accumulator live
in VMEM scratch across KV steps.  Block shapes are MXU-aligned (256-lane
blocks, head_dim on the minor axis).  Causal masking is applied at element
granularity inside the block and fully-masked KV blocks are skipped with
``pl.when`` (no FLOPs spent on the upper triangle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLOCK = 256
KV_BLOCK = 256
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, window, kv_blocks: int,
                 seq_q: int, seq_kv: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    shift = seq_kv - seq_q
    q_pos = (qi * Q_BLOCK + shift
             + jax.lax.broadcasted_iota(jnp.int32, (Q_BLOCK, 1), 0))
    k_pos = kj * KV_BLOCK + jax.lax.broadcasted_iota(
        jnp.int32, (1, KV_BLOCK), 1)

    # block-level skip: causal upper triangle / outside the SWA band
    run = kj >= 0
    if causal:
        run &= kj * KV_BLOCK <= qi * Q_BLOCK + shift + Q_BLOCK - 1
    if window is not None:
        run &= (kj + 1) * KV_BLOCK - 1 > qi * Q_BLOCK + shift - window

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # [QB, D]
        k = k_ref[0].astype(jnp.float32)                  # [KB, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < seq_kv
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == kv_blocks - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    interpret: bool = True) -> jax.Array:
    """q/k/v: [B, H, S, D] (KV already repeated to H heads).  Returns same."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    scale = d ** -0.5
    q_pad = (-sq) % Q_BLOCK
    kv_pad = (-skv) % KV_BLOCK
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
    bh = b * h
    qf = q.reshape(bh, -1, d)
    kf = k.reshape(bh, -1, d)
    vf = v.reshape(bh, -1, d)
    q_blocks = qf.shape[1] // Q_BLOCK
    kv_blocks = kf.shape[1] // KV_BLOCK
    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window,
        kv_blocks=kv_blocks, seq_q=sq, seq_kv=skv, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(bh, q_blocks, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, Q_BLOCK, d), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, KV_BLOCK, d), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, KV_BLOCK, d), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q_BLOCK, d), lambda b_, i, j: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Q_BLOCK, 1), jnp.float32),
            pltpu.VMEM((Q_BLOCK, 1), jnp.float32),
            pltpu.VMEM((Q_BLOCK, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, -1, d)[:, :, :sq]
