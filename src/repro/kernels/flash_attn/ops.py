"""Jitted public wrapper for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention
from repro.kernels.flash_attn.ref import flash_attention_ref


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              impl: str = "pallas", interpret: bool = True) -> jax.Array:
    """GQA-aware entry point: repeats KV heads to match q heads."""
    if k.shape[1] != q.shape[1]:
        rep = q.shape[1] // k.shape[1]
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if impl == "ref":
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           interpret=interpret)
