"""Pure-jnp oracle for decode attention (one token vs. a KV cache)."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, valid_len):
    """q: [B, H, D]; caches: [B, H, S, D]; valid_len: int or [B].

    Returns [B, H, D].  Slots >= valid_len are masked out.
    """
    b, h, s, d = k_cache.shape
    scale = d ** -0.5
    scores = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = jnp.asarray(valid_len)
    valid = valid if valid.ndim else jnp.broadcast_to(valid, (b,))
    mask = jnp.arange(s)[None, :] < valid[:, None]
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    p = jnp.exp(scores - jnp.max(scores, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)
