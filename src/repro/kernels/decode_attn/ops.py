"""Jitted public wrapper for decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn.kernel import decode_attention
from repro.kernels.decode_attn.ref import decode_attention_ref


def decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
           valid_len, *, impl: str = "pallas",
           interpret: bool = True) -> jax.Array:
    """GQA-aware: repeats KV heads to match q heads."""
    if k_cache.shape[1] != q.shape[1]:
        rep = q.shape[1] // k_cache.shape[1]
        k_cache = jnp.repeat(k_cache, rep, axis=1)
        v_cache = jnp.repeat(v_cache, rep, axis=1)
    if impl == "ref":
        return decode_attention_ref(q, k_cache, v_cache, valid_len)
    return decode_attention(q, k_cache, v_cache, valid_len,
                            interpret=interpret)
