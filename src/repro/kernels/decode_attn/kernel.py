"""Pallas TPU decode attention (FlashDecoding-style split-KV).

The serving analogue of the LSM state read (DESIGN.md §2): one query token
reads a long cache.  Decode is memory-bound, so the kernel's job is to
stream the KV cache HBM->VMEM exactly once at full bandwidth: grid
(batch*heads, kv_blocks) with the cache block-tiled on the S axis and the
online-softmax statistics (m, l, acc) carried in VMEM scratch across KV
blocks.  Blocks past the valid length are skipped entirely (``pl.when``),
so ragged caches don't waste bandwidth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

KV_BLOCK = 512
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, kv_blocks: int, scale: float):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid_len = len_ref[0]

    @pl.when(kj * KV_BLOCK < valid_len)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # [1, D]
        k = k_ref[0].astype(jnp.float32)                  # [KB, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [1, KB]
        pos = kj * KV_BLOCK + jax.lax.broadcasted_iota(
            jnp.int32, (1, KV_BLOCK), 1)
        s = jnp.where(pos < valid_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # [1, D]
        m_ref[...] = m_new

    @pl.when(kj == kv_blocks - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_len: jax.Array, *,
                     interpret: bool = True) -> jax.Array:
    """q: [B, H, D]; caches: [B, H, S, D]; valid_len: scalar or [B] int32.

    Returns [B, H, D] (KV heads already repeated to H by the caller)."""
    b, h, d = q.shape
    s = k_cache.shape[2]
    scale = d ** -0.5
    pad = (-s) % KV_BLOCK
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    sp = s + pad
    kv_blocks = sp // KV_BLOCK
    valid = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    valid_bh = jnp.repeat(valid, h)                       # [B*H]
    kernel = functools.partial(_decode_kernel, kv_blocks=kv_blocks,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bh, j: (bh, 0, 0)),
            pl.BlockSpec((1, KV_BLOCK, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, KV_BLOCK, d), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1,), lambda bh, j: (bh,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, j: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(b * h, 1, d), k_cache.reshape(b * h, sp, d),
      v_cache.reshape(b * h, sp, d), valid_bh)
    return out.reshape(b, h, d)
