"""Jitted public wrapper for keyed window aggregation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.window_agg.kernel import window_agg
from repro.kernels.window_agg.ref import window_agg_ref


def aggregate(seg_ids: jax.Array, values: jax.Array, n_segments: int, *,
              impl: str = "pallas", interpret: bool = True):
    """seg_ids in [0, n_segments); returns (sums [S, V], counts [S]).

    Degenerate shapes short-circuit: with no events the kernel's grid has a
    zero-length accumulation axis and would return uninitialized output
    blocks, so both impls answer zeros directly."""
    if int(values.shape[0]) == 0 or n_segments == 0:
        return (jnp.zeros((n_segments, int(values.shape[1])), jnp.float32),
                jnp.zeros(n_segments, jnp.float32))
    if impl == "ref":
        return window_agg_ref(seg_ids, values, n_segments)
    return window_agg(seg_ids, values, n_segments, interpret=interpret)
