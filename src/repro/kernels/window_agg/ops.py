"""Jitted public wrapper for keyed window aggregation."""
from __future__ import annotations

import jax

from repro.kernels.window_agg.kernel import window_agg
from repro.kernels.window_agg.ref import window_agg_ref


def aggregate(seg_ids: jax.Array, values: jax.Array, n_segments: int, *,
              impl: str = "pallas", interpret: bool = True):
    if impl == "ref":
        return window_agg_ref(seg_ids, values, n_segments)
    return window_agg(seg_ids, values, n_segments, interpret=interpret)
