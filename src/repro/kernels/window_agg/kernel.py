"""Pallas TPU kernel: keyed window aggregation (segment sum).

TPU adaptation (DESIGN.md §3): scatter-add — the GPU/CPU idiom for keyed
aggregation — has no efficient TPU analogue (no per-lane atomics).  The
MXU-native formulation is a one-hot matmul: for an event tile with segment
ids s and values v,  sums += one_hot(s)ᵀ @ v  — a dense [E, S_blk]x[E, V]
product on the systolic array.  The segment axis is blocked over the grid so
the one-hot never exceeds a VMEM tile; event tiles stream sequentially and
accumulate.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EVENT_TILE = 1024
SEG_BLOCK = 512


def _agg_kernel(seg_ref, val_ref, sum_ref, cnt_ref):
    j = pl.program_id(1)                       # event-tile index (sequential)

    @pl.when(j == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    i = pl.program_id(0)                       # segment-block index
    seg = seg_ref[...]                         # [EVENT_TILE]
    val = val_ref[...]                         # [EVENT_TILE, V]
    local = seg - i * SEG_BLOCK
    onehot = (local[:, None] ==
              jnp.arange(SEG_BLOCK)[None, :]).astype(val.dtype)
    sum_ref[...] += jnp.einsum("es,ev->sv", onehot, val,
                               preferred_element_type=jnp.float32)
    cnt_ref[...] += jnp.sum(onehot, axis=0)


@partial(jax.jit, static_argnames=("n_segments", "interpret"))
def window_agg(seg_ids: jax.Array, values: jax.Array, n_segments: int, *,
               interpret: bool = True):
    """seg_ids: [N] int32; values: [N, V] f32.  Returns (sums, counts)."""
    n, v = values.shape
    n_pad = (-n) % EVENT_TILE
    if n_pad:
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full(n_pad, -1, seg_ids.dtype)])  # -1 matches none
        values = jnp.concatenate([values, jnp.zeros((n_pad, v), values.dtype)])
    s_pad = (-n_segments) % SEG_BLOCK
    n_seg_padded = n_segments + s_pad
    grid = (n_seg_padded // SEG_BLOCK, values.shape[0] // EVENT_TILE)
    sums, counts = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((EVENT_TILE,), lambda i, j: (j,)),
            pl.BlockSpec((EVENT_TILE, v), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((SEG_BLOCK, v), lambda i, j: (i, 0)),
            pl.BlockSpec((SEG_BLOCK,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_seg_padded, v), jnp.float32),
            jax.ShapeDtypeStruct((n_seg_padded,), jnp.float32),
        ],
        interpret=interpret,
    )(seg_ids, values)
    return sums[:n_segments], counts[:n_segments]
