"""Pure-jnp oracle for keyed window aggregation (segment sum + count)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def window_agg_ref(seg_ids: jnp.ndarray, values: jnp.ndarray, n_segments: int):
    """seg_ids: [N] int32 in [0, n_segments); values: [N, V] float32.

    Returns (sums [n_segments, V], counts [n_segments]).
    """
    sums = jax.ops.segment_sum(values, seg_ids, num_segments=n_segments)
    counts = jax.ops.segment_sum(jnp.ones_like(seg_ids, jnp.float32), seg_ids,
                                 num_segments=n_segments)
    return sums, counts
