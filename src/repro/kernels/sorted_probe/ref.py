"""Pure-jnp oracle for the sorted-run probe (LSM SSTable lookup)."""
from __future__ import annotations

import jax.numpy as jnp


def sorted_probe_ref(table: jnp.ndarray, queries: jnp.ndarray):
    """table: [T] sorted int keys; queries: [N] int keys.

    Returns (pos [N] int32, found [N] bool): pos = number of table entries
    strictly less than the query (== insertion point == index of the match
    when present).
    """
    pos = jnp.searchsorted(table, queries, side="left").astype(jnp.int32)
    pos_c = jnp.clip(pos, 0, table.shape[0] - 1)
    found = table[pos_c] == queries
    return pos, found
