"""Jitted public wrapper for the sorted-run probe."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.sorted_probe.kernel import sorted_probe
from repro.kernels.sorted_probe.ref import sorted_probe_ref


def probe(table: jax.Array, queries: jax.Array, *,
          impl: str = "pallas", interpret: bool = True):
    """impl: "pallas" (TPU kernel; interpret=True executes on CPU) | "ref".

    Returns (pos [N] int32, found [N] bool); pos is the insertion point
    (== index of the match where found).  Degenerate shapes short-circuit:
    an empty table finds nothing at rank 0 (the ref's clipped gather would
    index out of bounds), an empty query batch returns empties."""
    n = int(queries.shape[0])
    if int(table.shape[0]) == 0 or n == 0:
        return jnp.zeros(n, jnp.int32), jnp.zeros(n, bool)
    if impl == "ref":
        return sorted_probe_ref(table, queries)
    return sorted_probe(table, queries, interpret=interpret)
