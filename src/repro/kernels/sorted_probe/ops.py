"""Jitted public wrapper for the sorted-run probe."""
from __future__ import annotations

import jax

from repro.kernels.sorted_probe.kernel import sorted_probe
from repro.kernels.sorted_probe.ref import sorted_probe_ref


def probe(table: jax.Array, queries: jax.Array, *,
          impl: str = "pallas", interpret: bool = True):
    """impl: "pallas" (TPU kernel; interpret=True executes on CPU) | "ref"."""
    if impl == "ref":
        return sorted_probe_ref(table, queries)
    return sorted_probe(table, queries, interpret=interpret)
