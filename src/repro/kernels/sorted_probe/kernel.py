"""Pallas TPU kernel: batched probe of a sorted run (the LSM read hot spot).

TPU adaptation of RocksDB's per-key binary search (DESIGN.md §3): binary
search is a scalar, branch-heavy loop — hostile to the VPU.  Instead each
(query block x table tile) cell computes a dense comparison matrix and
reduces it: ``rank += sum(tile < q)`` — an O(T) but fully vectorized
rank computation whose arithmetic intensity fits the 8x128 vector lanes.
Table tiles stream HBM->VMEM via the BlockSpec index map; ranks accumulate
across the (sequential) tile grid dimension.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QUERY_BLOCK = 512
TABLE_TILE = 2048


def _probe_kernel(table_ref, query_ref, pos_ref, found_ref):
    j = pl.program_id(1)                       # table-tile index (sequential)

    @pl.when(j == 0)
    def _init():
        pos_ref[...] = jnp.zeros_like(pos_ref)
        found_ref[...] = jnp.zeros_like(found_ref)

    tile = table_ref[...]                      # [TABLE_TILE]
    q = query_ref[...]                         # [QUERY_BLOCK]
    # rank contribution: entries strictly less than the query
    less = tile[None, :] < q[:, None]          # [QB, TT]
    pos_ref[...] += jnp.sum(less, axis=1).astype(jnp.int32)
    # match check: the tile entry at the local insertion point
    eq = tile[None, :] == q[:, None]
    found_ref[...] |= jnp.any(eq, axis=1)


@partial(jax.jit, static_argnames=("interpret",))
def sorted_probe(table: jax.Array, queries: jax.Array, *,
                 interpret: bool = True):
    """table: [T] sorted int32/int64 (padded with INT_MAX to a tile multiple
    by the caller or here); queries: [N].  Returns (pos [N], found [N])."""
    t, n = table.shape[0], queries.shape[0]
    dtype = table.dtype
    maxval = jnp.iinfo(dtype).max
    t_pad = (-t) % TABLE_TILE
    n_pad = (-n) % QUERY_BLOCK
    if t_pad:
        table = jnp.concatenate([table, jnp.full(t_pad, maxval, dtype)])
    if n_pad:
        queries = jnp.concatenate([queries, jnp.full(n_pad, maxval, dtype)])
    grid = (queries.shape[0] // QUERY_BLOCK, table.shape[0] // TABLE_TILE)
    pos, found = pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TABLE_TILE,), lambda i, j: (j,)),
            pl.BlockSpec((QUERY_BLOCK,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((QUERY_BLOCK,), lambda i, j: (i,)),
            pl.BlockSpec((QUERY_BLOCK,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((queries.shape[0],), jnp.int32),
            jax.ShapeDtypeStruct((queries.shape[0],), jnp.bool_),
        ],
        interpret=interpret,
    )(table, queries)
    # the padded table tail is full of maxval: a genuine maxval query that
    # is absent from the real table would otherwise report found (its rank
    # lands exactly at t, past every real entry — mask it out)
    return pos[:n], found[:n] & (pos[:n] < t)
